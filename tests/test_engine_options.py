"""EngineOptions: one validated statics bundle + chunked continuation.

Pins the API-redesign contract: every per-call static lives in a frozen
``EngineOptions`` that validates *at construction* (invalid combos fail
before any tracing), ``TickEngine(options)`` and the network wrappers
accept it, the legacy kwargs shim still works behind a
``DeprecationWarning``, and ``TickEngine.chunk`` resumed K times for T
ticks is bit-identical to one K*T rollout -- the property continuous
admission is built on.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity
from repro.core.engine import EngineOptions, TickCarry, TickEngine
from repro.core.lif import LIFParams
from repro.core.network import (
    SNNParams, SNNState, learning_rollout, rollout,
)
from repro.plasticity import PlasticityParams, PlasticityState

jax.config.update("jax_platform_name", "cpu")

N = 12


def _params(n=N, *, seed=0):
    rng = np.random.default_rng(seed)
    c = connectivity.sparse_random(n, density=0.4, seed=seed)
    return SNNParams(
        w=jnp.asarray(rng.uniform(0, 2.0, (n, n)), jnp.float32),
        c=jnp.asarray(c, jnp.float32),
        w_in=jnp.eye(n, dtype=jnp.float32) * 2.0,
        lif=LIFParams.make(n, v_th=1.5, leak=0.25, r_ref=1))


def _ext(ticks, n=N, *, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.random((ticks, n)) < 0.35) * 1.0, jnp.float32)


class TestValidation:
    def test_defaults_validate(self):
        opts = EngineOptions()
        assert opts.backend == "jnp"
        assert opts.mode == "fixed_leak"

    def test_invalid_backend_fails_at_construction(self):
        with pytest.raises(ValueError, match="backend"):
            EngineOptions(backend="verilog")

    def test_invalid_mode_fails_at_construction(self):
        with pytest.raises(ValueError, match="mode"):
            EngineOptions(mode="midpoint")

    def test_knee_requires_fallback_overflow_eagerly(self):
        # The combo the lazy kwargs path only catches at rollout time
        # fails here before anything traces.
        with pytest.raises(ValueError, match="event_knee requires"):
            EngineOptions(backend="event", event_knee=4,
                          event_overflow="strict")

    def test_frozen(self):
        opts = EngineOptions()
        with pytest.raises(dataclasses.FrozenInstanceError):
            opts.backend = "event"


class TestTickEngineConstruction:
    def test_options_path(self):
        opts = EngineOptions(backend="event", event_k_active=4)
        eng = TickEngine(opts)
        assert eng.backend == "event"
        assert eng.event_k_active == 4
        assert eng.options == opts

    def test_options_path_does_not_warn(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            TickEngine(EngineOptions())
            TickEngine()   # all-defaults is not "legacy kwargs"

    def test_legacy_kwargs_warn(self):
        with pytest.warns(DeprecationWarning, match="EngineOptions"):
            eng = TickEngine(backend="event", event_k_active=4)
        assert eng.backend == "event"

    def test_options_and_kwargs_is_an_error(self):
        with pytest.raises(TypeError, match="ONE of"):
            TickEngine(EngineOptions(), backend="jnp")

    def test_unknown_kwarg_is_an_error(self):
        with pytest.raises(TypeError, match="unknown engine option"):
            TickEngine(backened="jnp")   # typo'd name

    def test_non_options_positional_is_an_error(self):
        with pytest.raises(TypeError):
            TickEngine("event")


class TestWrapperOptions:
    def test_rollout_options_matches_kwargs(self):
        params, ext = _params(), _ext(8)
        state = SNNState.zeros((), N)
        _, r1 = rollout(params, state, ext, 8, backend="jnp",
                        mode="euler")
        _, r2 = rollout(params, state, ext, 8,
                        options=EngineOptions(backend="jnp", mode="euler"))
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))

    def test_learning_rollout_options(self):
        params, ext = _params(), _ext(8)
        state = SNNState.zeros((), N)
        pstate = PlasticityState.zeros((), N)
        pp = PlasticityParams.make()
        (_, _, w1), _ = learning_rollout(
            params, state, pstate, ext, 8, plasticity=pp)
        (_, _, w2), _ = learning_rollout(
            params, state, pstate, ext, 8,
            options=EngineOptions(plasticity=pp))
        np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))

    def test_plan_engine_options(self):
        from repro.core.dispatch_policy import plan as plan_dispatch

        params = _params()
        plan = plan_dispatch(np.asarray(params.c))
        opts = plan.engine_options()
        assert isinstance(opts, EngineOptions)
        assert opts.backend in ("jnp", "event")
        assert plan.engine_kwargs()["backend"] == opts.backend


class TestChunkedContinuation:
    @pytest.mark.parametrize("chunk", [1, 3, 4])
    def test_chunks_bitexact_vs_one_shot(self, chunk):
        T = 12
        params, ext = _params(), _ext(T)
        eng = TickEngine(EngineOptions())
        state = SNNState.zeros((), N)
        _, raster_ref = eng.rollout(params, state, ext, T)

        carry = TickCarry(state=state)
        rasters = []
        for k in range(0, T, chunk):
            carry, raster = eng.chunk(params, carry, ext[k:k + chunk], chunk)
            rasters.append(np.asarray(raster))
        np.testing.assert_array_equal(
            np.concatenate(rasters), np.asarray(raster_ref))

    @pytest.mark.parametrize("chunk", [2, 5])
    def test_learning_chunks_bitexact_incl_learn_until(self, chunk):
        T, budget = 10, 7
        params, ext = _params(), _ext(T)
        eng = TickEngine(EngineOptions(plasticity=PlasticityParams.make()))
        state = SNNState.zeros((), N)
        pstate = PlasticityState.zeros((), N)
        (st1, ps1, w1), raster_ref = eng.learning_rollout(
            params, state, pstate, ext, T, learn_until=budget)

        carry = eng.init_learning_carry(params, state, pstate)
        rasters = []
        for k in range(0, T, chunk):
            n = min(chunk, T - k)
            carry, raster = eng.chunk(params, carry, ext[k:k + n], n,
                                      learn_until=budget)
            rasters.append(np.asarray(raster))
        np.testing.assert_array_equal(
            np.concatenate(rasters), np.asarray(raster_ref))
        np.testing.assert_array_equal(np.asarray(carry.w), np.asarray(w1))
