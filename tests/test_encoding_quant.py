"""Spike encoders/decoders + u8 quantization."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="tier-1 property tests need the 'test' extra")
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import encoding, quant


class TestEncoding:
    def test_binarize(self):
        x = jnp.asarray([0.0, 0.4, 0.6, 1.0])
        np.testing.assert_array_equal(encoding.binarize(x, 0.5), [0, 0, 1, 1])

    @settings(deadline=None, max_examples=40)
    @given(st.floats(0, 1), st.integers(1, 32))
    def test_rate_code_count_matches_value(self, frac, n_ticks):
        spikes = encoding.rate_encode(jnp.asarray([frac]), n_ticks)
        count = float(spikes.sum())
        assert abs(count - round(frac * n_ticks)) <= 1

    def test_level_encode_matches_fig5(self):
        # Fig. 5 impulse registers: quantized feature levels like 01/02/04.
        x = jnp.asarray([0.25, 0.5, 1.0, 0.0])
        np.testing.assert_array_equal(encoding.level_encode(x, levels=4),
                                      [1, 2, 4, 0])

    def test_latency_earlier_for_stronger(self):
        sp = encoding.latency_encode(jnp.asarray([1.0, 0.5, 0.0]), 8)
        first = np.argmax(np.asarray(sp), axis=0)
        assert first[0] < first[1]
        assert np.asarray(sp)[:, 2].sum() == 0  # zero input never spikes

    def test_decoders(self):
        t, n = 6, 3
        spikes = np.zeros((t, n), np.float32)
        spikes[1, 2] = 1
        spikes[2:5, 0] = 1
        sp = jnp.asarray(spikes)
        assert int(encoding.decode_spike_count(sp)) == 0       # most spikes
        assert int(encoding.decode_first_spike(sp)) == 2       # earliest


class TestQuant:
    @settings(deadline=None, max_examples=30)
    @given(st.integers(0, 2**31 - 1))
    def test_u8_roundtrip_error_bounded(self, seed):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.uniform(0, 3, (16, 16)).astype(np.float32))
        qw = quant.quantize_u8(w)
        back = quant.dequantize_u8(qw)
        assert float(jnp.abs(back - w).max()) <= float(qw.scale) / 2 + 1e-6

    def test_signed_split_reconstructs(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
        pos, neg = quant.quantize_signed(w)
        recon = quant.dequantize_u8(pos) - quant.dequantize_u8(neg)
        assert float(jnp.abs(recon - w).max()) <= float(pos.scale) + 1e-6

    def test_integer_network_semantics(self):
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
        v_th = jnp.asarray(rng.uniform(0.5, 1.5, 8).astype(np.float32))
        w_int, th_int, scale = quant.integer_network(w, v_th)
        assert w_int.dtype == jnp.int32 and th_int.dtype == jnp.int32
        # integer weights on the shared grid approximate w / scale
        np.testing.assert_allclose(
            np.asarray(w_int) * float(scale), np.asarray(w), atol=float(scale))
