"""Multi-tenant SNN serving: one compiled tick program, many networks.

Pins the acceptance criteria: >= 8 heterogeneous tenants (different C
topologies and LIF registers, incl. a plastic one) through ONE jitted
program with zero recompiles across tenant swaps; frozen tenants come
back bit-identical from the shared learning datapath; the served
datapath equals the core engine run tenant-by-tenant; per-request tick
budgets mask, never retrace.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity
from repro.core.lif import LIFParams
from repro.core.network import SNNParams, SNNState, rollout
from repro.core.registers import RegisterBank, WeightLayout
from repro.launch.serve import (
    ServeRequest, SNNServer, make_demo_requests, make_demo_tenants,
)

jax.config.update("jax_platform_name", "cpu")

N_MAX = 16


def _server(**kw):
    kw.setdefault("n_max", N_MAX)
    kw.setdefault("slots", 4)
    kw.setdefault("max_ticks", 10)
    return SNNServer(**kw)


def _layered_bank(n_in, n_out, *, w=120, th=80, seed=0):
    n = n_in + n_out
    bank = RegisterBank(n, weight_layout=WeightLayout.PER_SYNAPSE)
    c = connectivity.layered([n_in, n_out])
    bank.set_connection_list(c)
    rng = np.random.default_rng(seed)
    bank.set_weights((rng.integers(w // 2, w, (n, n)) * c).astype(np.uint8))
    bank.set_thresholds(np.full((n,), th, np.uint8))
    return bank


def _drive(t, n_in, *, mag=200.0, p=0.5, seed=0):
    rng = np.random.default_rng(seed)
    return ((rng.random((t, n_in)) < p) * mag).astype(np.float32)


class TestOneProgramManyTenants:
    def test_eight_heterogeneous_tenants_zero_recompiles(self):
        server = _server(slots=4)
        names = make_demo_tenants(server, 8, seed=1)
        assert len(names) == 8
        # heterogeneous: multiple topologies and register settings
        cs = [np.asarray(server.tenants[n].params.c) for n in names]
        assert len({c.tobytes() for c in cs}) == 8
        reqs = make_demo_requests(server, names, 16, seed=2)
        stats = server.serve(reqs)
        assert stats["n_requests"] == 16
        assert stats["n_tenants"] == 8
        assert stats["compiles"] == 1, "slot/tenant churn must not retrace"
        assert stats["recompiles_after_warmup"] == 0
        # serving again (new tenants swapped through the same slots) stays warm
        stats2 = server.serve(make_demo_requests(server, names, 8, seed=3))
        assert stats2["compiles"] == 1
        assert stats2["recompiles_after_warmup"] == 0

    def test_served_wave_matches_core_engine_per_tenant(self):
        """The slot axis is transparent: serving == rollout, tenant by tenant."""
        server = _server(slots=4, max_ticks=8)
        names = make_demo_tenants(server, 4, seed=5)
        reqs = make_demo_requests(server, names, 4, seed=6)
        stats = server.serve(reqs)
        for r in reqs:
            t = server.tenants[r.tenant]
            ext = np.zeros((server.max_ticks, server.n_max), np.float32)
            ext[: r.ext.shape[0], : r.ext.shape[1]] = r.ext
            st0 = SNNState.zeros((), server.n_max)
            _, raster = rollout(t.params, st0, jnp.asarray(ext),
                                server.max_ticks)
            counts = np.asarray(raster)[: r.n_ticks].sum(0)
            expect = counts[t.n - t.n_out : t.n]
            np.testing.assert_array_equal(r.counts, expect)
        assert stats["recompiles_after_warmup"] == 0

    def test_rate_decoded_argmax(self):
        """A tenant wired so output neuron 1 dominates decodes to pred=1."""
        server = _server(slots=2, max_ticks=8)
        n_in, n_out = 3, 3
        bank = _layered_bank(n_in, n_out, seed=0)
        w = np.zeros((n_in + n_out, n_in + n_out), np.uint8)
        w[:n_in, n_in + 1] = 250          # all inputs drive output neuron 1
        bank.set_weights(w)
        bank.set_thresholds(np.full((n_in + n_out,), 50, np.uint8))
        server.add_tenant("biased", bank, n_in=n_in, n_out=n_out)
        req = ServeRequest(rid=0, tenant="biased",
                         ext=_drive(8, n_in, seed=1), n_ticks=8)
        server.serve([req])
        assert req.pred == 1
        assert req.counts[1] > 0

    def test_tick_budget_masks_not_retraces(self):
        server = _server(slots=2, max_ticks=10)
        bank = _layered_bank(4, 2, seed=3)
        server.add_tenant("t", bank, n_in=4, n_out=2)
        ext = _drive(10, 4, seed=4)
        full = ServeRequest(rid=0, tenant="t", ext=ext, n_ticks=10)
        short = ServeRequest(rid=1, tenant="t", ext=ext, n_ticks=3)
        server.serve([full, short])
        assert server.compiles == 1
        assert short.counts.sum() <= full.counts.sum()
        # budget-3 counts == the first 3 ticks of the full raster
        t = server.tenants["t"]
        pad = np.zeros((10, server.n_max), np.float32)
        pad[:, :4] = ext
        _, raster = rollout(t.params, SNNState.zeros((), server.n_max),
                            jnp.asarray(pad), 10)
        expect = np.asarray(raster)[:3].sum(0)[t.n - t.n_out : t.n]
        np.testing.assert_array_equal(short.counts, expect)


class TestEventTenancy:
    """Sparse tenants pick the event program per slot (DESIGN.md §10)."""

    def _sparse_bank(self, n, *, seed):
        rng = np.random.default_rng(seed)
        c = connectivity.sparse_random(n, 0.1, seed=seed)
        bank = RegisterBank(n, weight_layout=WeightLayout.PER_SYNAPSE)
        bank.set_connection_list(c)
        bank.set_weights((rng.integers(60, 200, (n, n)) * c).astype(np.uint8))
        bank.set_thresholds(np.full((n,), 70, np.uint8))
        return bank

    def test_sparse_tenant_routes_to_event_backend(self):
        server = _server(event_density=0.2)
        server.add_tenant("sparse", self._sparse_bank(N_MAX, seed=20),
                          n_in=N_MAX, n_out=N_MAX)
        server.add_tenant("dense", _layered_bank(8, 8, seed=21), n_in=8,
                          n_out=8)
        assert server.tenants["sparse"].backend == "event"
        assert server.tenants["sparse"].fan_idx.shape == (
            N_MAX, server.event_cap)
        assert server.tenants["dense"].backend == "jnp"
        assert server.tenants["dense"].fan_idx is None

    def test_event_disabled_by_default(self):
        server = _server()
        server.add_tenant("sparse", self._sparse_bank(N_MAX, seed=22),
                          n_in=N_MAX, n_out=N_MAX)
        assert server.tenants["sparse"].backend == "jnp"

    def test_mixed_waves_one_compile_per_backend_zero_recompiles(self):
        server = _server(slots=2, event_density=0.2)
        server.add_tenant("s0", self._sparse_bank(N_MAX, seed=23),
                          n_in=N_MAX, n_out=N_MAX)
        server.add_tenant("s1", self._sparse_bank(N_MAX, seed=24),
                          n_in=N_MAX, n_out=N_MAX)
        server.add_tenant("d0", _layered_bank(8, 8, seed=25), n_in=8, n_out=8)
        reqs = []
        for i, name in enumerate(["s0", "d0", "s1", "d0", "s0"]):
            t = server.tenants[name]
            reqs.append(ServeRequest(rid=i, tenant=name,
                                   ext=_drive(6, t.n_in, seed=30 + i),
                                   n_ticks=6))
        stats = server.serve(reqs)
        assert stats["n_requests"] == 5
        assert stats["backends"] == {"event": 3, "jnp": 2}
        assert stats["compiles"] == 2          # one per resident program
        assert stats["recompiles_after_warmup"] == 0
        # a second mixed queue stays warm on both programs
        stats2 = server.serve([ServeRequest(
            rid=9, tenant=name, ext=_drive(5, server.tenants[name].n_in,
                                           seed=40), n_ticks=5)
            for name in ("s1", "d0")])
        assert stats2["compiles"] == 2
        assert stats2["recompiles_after_warmup"] == 0

    def test_event_wave_matches_core_engine_rollout(self):
        """The event program's served raster equals the plain jnp rollout
        tenant-by-tenant (bit-exact at fabric size)."""
        server = _server(slots=2, max_ticks=8, event_density=0.2)
        server.add_tenant("s", self._sparse_bank(N_MAX, seed=26),
                          n_in=N_MAX, n_out=N_MAX)
        req = ServeRequest(rid=0, tenant="s", ext=_drive(8, N_MAX, seed=27),
                         n_ticks=8)
        server.serve([req])
        t = server.tenants["s"]
        ext = np.zeros((8, N_MAX), np.float32)
        ext[: req.ext.shape[0]] = req.ext
        _, raster = rollout(t.params, SNNState.zeros((), N_MAX),
                            jnp.asarray(ext), 8)
        np.testing.assert_array_equal(
            req.counts, np.asarray(raster).sum(0)[t.n - t.n_out : t.n])

    def test_hub_tenant_exceeding_cap_stays_dense(self):
        """A sparse-by-density tenant with one hub neuron above the fan-in
        cap must NOT ride the event program (the cap never truncates)."""
        n = N_MAX
        c = np.zeros((n, n), np.bool_)
        c[:, 0] = True            # hub in-degree n > default cap n//4
        c[0, 0] = False
        bank = RegisterBank(n, weight_layout=WeightLayout.PER_SYNAPSE)
        bank.set_connection_list(c)
        bank.set_weights((np.full((n, n), 90) * c).astype(np.uint8))
        bank.set_thresholds(np.full((n,), 70, np.uint8))
        server = _server(event_density=0.2)
        server.add_tenant("hub", bank, n_in=n, n_out=n)
        assert server.tenants["hub"].density <= 0.2
        assert server.tenants["hub"].backend == "jnp"


class TestPlasticTenancy:
    def test_frozen_tenants_bit_identical_plastic_learns(self):
        server = _server(slots=4, max_ticks=10)
        frozen_bank = _layered_bank(4, 4, seed=7)
        plastic_bank = _layered_bank(4, 4, seed=7)   # same image, one learns
        server.add_tenant("frozen", frozen_bank, n_in=4, n_out=4)
        server.add_tenant("plastic", plastic_bank, n_in=4, n_out=4,
                          plastic=True)
        w_frozen0 = np.asarray(server.tenants["frozen"].params.w).copy()
        w_plastic0 = np.asarray(server.tenants["plastic"].params.w).copy()
        np.testing.assert_array_equal(w_frozen0, w_plastic0)

        ext = _drive(10, 4, p=0.7, seed=8)
        for wave in range(3):
            server.serve([
                ServeRequest(rid=0, tenant="frozen", ext=ext, n_ticks=10),
                ServeRequest(rid=1, tenant="plastic", ext=ext, n_ticks=10),
            ])
        w_frozen1 = np.asarray(server.tenants["frozen"].params.w)
        w_plastic1 = np.asarray(server.tenants["plastic"].params.w)
        # shared learning datapath, exact no-op for the frozen tenant
        np.testing.assert_array_equal(w_frozen0, w_frozen1)
        # the plastic tenant's registers moved (write-back across waves)
        assert not np.array_equal(w_plastic0, w_plastic1)
        # and stayed in the u8 register domain (serializable to the bank)
        assert w_plastic1.min() >= 0.0 and w_plastic1.max() <= 255.0
        assert server.compiles == 1

    def test_same_plastic_tenant_twice_equals_sequential(self):
        """Two requests for one plastic tenant must not race on write-back:
        admission defers the duplicate, so the result equals serving them
        strictly one after the other."""
        def build():
            server = _server(slots=2, max_ticks=8)
            server.add_tenant("p", _layered_bank(4, 4, seed=12), n_in=4,
                              n_out=4, plastic=True)
            return server

        e1, e2 = _drive(8, 4, p=0.7, seed=13), _drive(8, 4, p=0.7, seed=14)
        together = build()
        together.serve([
            ServeRequest(rid=0, tenant="p", ext=e1, n_ticks=8),
            ServeRequest(rid=1, tenant="p", ext=e2, n_ticks=8)])
        sequential = build()
        sequential.serve([ServeRequest(rid=0, tenant="p", ext=e1, n_ticks=8)])
        sequential.serve([ServeRequest(rid=1, tenant="p", ext=e2, n_ticks=8)])
        np.testing.assert_array_equal(
            np.asarray(together.tenants["p"].params.w),
            np.asarray(sequential.tenants["p"].params.w))

    def test_budget_gates_learning_not_just_decode(self):
        """A request's persisted weights must not depend on the server's
        max_ticks ceiling: learning stops at the request's own budget."""
        ext = _drive(6, 4, p=0.8, seed=15)

        def learned_w(max_ticks):
            server = _server(slots=2, max_ticks=max_ticks)
            server.add_tenant("p", _layered_bank(4, 4, seed=16), n_in=4,
                              n_out=4, plastic=True)
            server.serve([ServeRequest(rid=0, tenant="p", ext=ext, n_ticks=6)])
            return np.asarray(server.tenants["p"].params.w)

        np.testing.assert_array_equal(learned_w(6), learned_w(12))

    def test_serve_empty_queue(self):
        server = _server()
        stats = server.serve([])
        assert stats["n_requests"] == 0 and stats["waves"] == 0
        assert stats["requests_served"] == 0
        assert stats["mean_ttft_s"] == 0.0  # never np.mean([])

    def test_serve_fully_rejected_queue(self):
        """Every request names an unknown tenant: zero report, counted
        rejections, no KeyError mid-wave."""
        server = _server()
        bad = [ServeRequest(rid=i, tenant=f"ghost-{i}",
                          ext=np.zeros((4, 4), np.float32), n_ticks=4)
               for i in range(3)]
        stats = server.serve(bad)
        assert stats["requests_served"] == 0
        assert stats["requests_rejected"] == 3
        assert stats["waves"] == 0 and stats["mean_ttft_s"] == 0.0

    def test_rectangular_w_in_pads(self):
        import dataclasses as dc
        from repro.launch.serve import pad_tenant_params
        from repro.core.network import params_from_registers

        bank = _layered_bank(3, 3, seed=17)
        p = params_from_registers(bank)
        p = dc.replace(p, w_in=p.w_in[:3])        # (n_in, n) input map
        padded = pad_tenant_params(p, N_MAX)
        assert padded.w_in.shape == (N_MAX, N_MAX)
        np.testing.assert_array_equal(np.asarray(padded.w_in[:3, :6]),
                                      np.asarray(p.w_in))

    def test_plastic_writeback_only_touches_routed_synapses(self):
        server = _server(slots=2, max_ticks=10)
        bank = _layered_bank(4, 4, seed=9)
        t = server.add_tenant("p", bank, n_in=4, n_out=4, plastic=True)
        w0 = np.asarray(t.params.w).copy()
        c = np.asarray(t.params.c)
        ext = _drive(10, 4, p=0.8, seed=10)
        server.serve([ServeRequest(rid=0, tenant="p", ext=ext, n_ticks=10)])
        w1 = np.asarray(server.tenants["p"].params.w)
        np.testing.assert_array_equal(w0[c == 0], w1[c == 0])


class TestPadding:
    def test_padded_neurons_never_spike(self):
        server = _server(slots=2, max_ticks=8)
        bank = _layered_bank(3, 2, seed=11)
        t = server.add_tenant("small", bank, n_in=3, n_out=2)
        ext = np.full((8, 3), 255.0, np.float32)
        st0 = SNNState.zeros((), server.n_max)
        _, raster = rollout(t.params, st0,
                            jnp.asarray(np.pad(ext, ((0, 0), (0, server.n_max - 3)))),
                            8)
        assert float(np.asarray(raster)[:, t.n:].sum()) == 0.0

    def test_oversized_tenant_rejected(self):
        server = _server()
        bank = _layered_bank(N_MAX, 2)
        with pytest.raises(ValueError, match="fabric"):
            server.add_tenant("big", bank, n_in=N_MAX, n_out=2)


class TestSlotBatchedOps:
    def test_fused_lif_step_slots_matches_per_slot_loop(self):
        from repro.kernels import ops
        from repro.core.lif import LIFState

        rng = np.random.default_rng(0)
        S, B, n = 3, 2, 12
        params = []
        for s in range(S):
            c = connectivity.sparse_random(n, 0.5, seed=s).astype(np.float32)
            params.append(SNNParams(
                w=jnp.asarray(rng.uniform(0, 2, (n, n)), jnp.float32),
                c=jnp.asarray(c),
                w_in=jnp.eye(n, dtype=jnp.float32),
                lif=LIFParams.make(n, v_th=0.5 + s, leak=0.1 * s, r_ref=s % 2)))
        slotted = jax.tree.map(lambda *xs: jnp.stack(xs), *params)
        spikes = jnp.asarray((rng.random((S, B, n)) < 0.4), jnp.float32)
        ext = jnp.asarray(rng.uniform(0, 1, (S, B, n)), jnp.float32)
        state = LIFState(
            v=jnp.asarray(rng.uniform(0, 1, (S, B, n)), jnp.float32),
            r=jnp.zeros((S, B, n), jnp.int32),
            y=spikes)

        out = ops.fused_lif_step_slots(state, spikes, slotted, ext,
                                       mode="fixed_leak", interpret=True)
        for s in range(S):
            st_s = LIFState(v=state.v[s], r=state.r[s], y=state.y[s])
            ref = ops.fused_lif_step(st_s, spikes[s], params[s], ext[s],
                                     mode="fixed_leak", interpret=True)
            np.testing.assert_allclose(np.asarray(out.v[s]), np.asarray(ref.v),
                                       rtol=1e-6, atol=1e-6)
            np.testing.assert_array_equal(np.asarray(out.y[s]),
                                          np.asarray(ref.y))
