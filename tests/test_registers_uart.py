"""Register bank + UART codec: the paper's §II.C/§III.B arithmetic, exactly."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="tier-1 property tests need the 'test' extra")
import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.core import connectivity, uart
from repro.core.registers import (
    RegisterBank, TimingModel, WeightLayout, transaction_breakdown,
)


class TestPaperArithmetic:
    def test_74_neuron_breakdown(self):
        """§III.B: 740 CL + 74 thresholds + 74 weights + 10 impulses = 898."""
        bd = transaction_breakdown(74)
        assert bd.connection_list == 740
        assert bd.thresholds == 74
        assert bd.weights == 74
        assert bd.impulses == 10
        assert bd.total == 898

    def test_74_neuron_time_93_54_ms(self):
        bd = transaction_breakdown(74)
        assert abs(bd.time_s(TimingModel.PAPER) * 1e3 - 93.54) < 0.02

    def test_single_neuron_416us(self):
        bd = transaction_breakdown(1)
        assert bd.total == 4
        assert abs(bd.time_s(TimingModel.PAPER) * 1e6 - 416.68) < 1.0

    def test_wire_model_is_10x_paper(self):
        bd = transaction_breakdown(74)
        assert bd.time_s(TimingModel.WIRE_8N1) == pytest.approx(
            10 * bd.time_s(TimingModel.PAPER))


class TestRegisterBank:
    def test_serialize_roundtrip(self):
        rng = np.random.default_rng(0)
        bank = RegisterBank(74)
        bank.set_connection_list(connectivity.layered([64, 10]))
        bank.set_thresholds(rng.integers(0, 256, 74))
        bank.set_weights(rng.integers(0, 256, 74))
        bank.set_impulses(rng.integers(0, 2, 74))
        payload = bank.serialize()
        assert len(payload) == 898
        bank2 = RegisterBank(74)
        bank2.load_bytes(payload)
        np.testing.assert_array_equal(bank2.get_connection_list(), bank.get_connection_list())
        np.testing.assert_array_equal(bank2.thresholds, bank.thresholds)
        np.testing.assert_array_equal(bank2.weights, bank.weights)
        np.testing.assert_array_equal(bank2.get_impulses(), bank.get_impulses())

    def test_per_synapse_layout(self):
        bank = RegisterBank(8, weight_layout=WeightLayout.PER_SYNAPSE)
        assert bank.weights.shape == (8, 8)
        assert bank.breakdown().weights == 64

    def test_reprogram_never_changes_shapes(self):
        """The 'no re-synthesis' property: rewriting registers preserves
        array shapes, so jitted consumers never re-trace."""
        bank = RegisterBank(16)
        shapes0 = {k: v.shape for k, v in bank.as_dict().items()}
        bank.set_connection_list(connectivity.all_to_all(16))
        bank.set_thresholds(np.full(16, 7))
        shapes1 = {k: v.shape for k, v in bank.as_dict().items()}
        assert shapes0 == shapes1


class TestUART:
    def test_frame_roundtrip_exhaustive(self):
        for b in range(256):
            assert uart.decode_frame(uart.encode_frame(b)) == b

    @settings(deadline=None, max_examples=50)
    @given(st.binary(min_size=0, max_size=200))
    def test_stream_roundtrip(self, payload):
        assert uart.decode_stream(uart.encode_stream(payload)) == payload

    def test_bad_start_bit_rejected(self):
        bits = uart.encode_frame(0x41)
        bits[0] = 1
        with pytest.raises(ValueError):
            uart.decode_frame(bits)

    def test_wire_time(self):
        # 898 bytes at 9600-8N1 = 935.4 ms (vs paper's 93.54 ms figure)
        assert uart.wire_time_s(898) == pytest.approx(0.9354, rel=1e-3)

    def test_host_link_stats(self):
        link = uart.HostLink()
        link.send(b"abc")
        link.receive(b"de")
        assert link.stats.bytes_tx == 3 and link.stats.bytes_rx == 2


@settings(deadline=None, max_examples=30)
@given(st.integers(1, 200))
def test_breakdown_generalizes(n):
    """total = N*ceil(N/8) + 2N + ceil(N/8) for any N."""
    import math
    bd = transaction_breakdown(n)
    rb = math.ceil(n / 8)
    assert bd.total == n * rb + 2 * n + rb


@settings(deadline=None, max_examples=30)
@given(st.integers(2, 64), st.integers(0, 2**32 - 1))
def test_connectivity_pack_roundtrip(n, seed):
    c = connectivity.sparse_random(n, 0.5, seed=seed)
    packed = connectivity.pack_bits(c)
    np.testing.assert_array_equal(connectivity.unpack_bits(packed, n), c)
