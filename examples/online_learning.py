"""On-device learning on the MNIST-8x8 fabric: STDP features + R-STDP readout.

The paper's processor is inference-only: weights are trained off-chip and
streamed in over the UART.  This example runs the *NeuroCoreX direction*
(arXiv:2506.14138) on the same fabric -- all learning happens inside the
network tick loop, from a random init, with weights on the u8 register
grid at every tick:

  stage 1  64 inputs -> 64 feature neurons, pair STDP (unsupervised).
           Competition = fixed-leak thresholds + host-side homeostasis:
           every spike bumps the winner's *threshold register* (runtime
           reconfiguration, no re-synthesis -- the paper's register story
           doing double duty as the inhibition the fabric lacks).
  stage 2  64 features -> 10 outputs, R-STDP: eligibility accumulates
           during the presentation, a terminal +/- dopamine scalar (was
           the argmax right?) converts it into the weight update.
  readback the learned u8 weights serialize through the RegisterBank /
           UART byte protocol and are asserted to produce *identical
           spikes* after the round trip -- device -> host weight readback.

  PYTHONPATH=src python examples/online_learning.py [--fast]
"""
from __future__ import annotations

import argparse
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_bundle
from repro.configs.mnist_stdp import RUN, N_CLASSES, N_HIDDEN, N_INPUT
from repro.core import connectivity
from repro.core.engine import EngineOptions, TickEngine
from repro.core.lif import LIFParams
from repro.core.network import SNNParams, SNNState, params_from_registers
from repro.core.registers import RegisterBank, WeightLayout
from repro.data import mnist
from repro.plasticity import PlasticityState, apply_reward

jax.config.update("jax_platform_name", "cpu")

# One tick datapath, three uses: frozen inference, STDP feature learning,
# R-STDP readout learning -- all the same TickEngine body, different
# static plasticity configs (the hardware analogue: one fabric, two
# learning-engine register settings).
INFER = TickEngine()
FEATURE = TickEngine(EngineOptions(plasticity=RUN.feature))
READOUT = TickEngine(EngineOptions(plasticity=RUN.readout))


# ---------------------------------------------------------------------------
# network construction


def plastic_mask() -> jnp.ndarray:
    """Only the feed-forward input->hidden block learns."""
    return jnp.asarray(connectivity.layered([N_INPUT, N_HIDDEN]), jnp.float32)


def routing_mask() -> np.ndarray:
    """Feed-forward block + lateral hidden->hidden WTA block (no self-loops)."""
    c = connectivity.layered([N_INPUT, N_HIDDEN])
    lat = connectivity.all_to_all(N_HIDDEN)
    c[N_INPUT:, N_INPUT:] = lat
    return c


def with_lateral_inhibition(w: jnp.ndarray) -> jnp.ndarray:
    """Install the fixed negative WTA block (the on-chip inhibitory bank)."""
    lat = -RUN.lateral_inhibition * jnp.asarray(
        connectivity.all_to_all(N_HIDDEN), jnp.float32)
    return w.at[N_INPUT:, N_INPUT:].set(lat)


def feature_net(w: jnp.ndarray, theta: jnp.ndarray) -> SNNParams:
    """64 -> 64 feature net with a frozen WTA block; hidden thresholds carry theta."""
    n = N_INPUT + N_HIDDEN
    c = jnp.asarray(routing_mask(), jnp.float32)
    v_th = jnp.ones((n,)).at[N_INPUT:].set(RUN.v_th_base + theta)
    leak = jnp.zeros((n,)).at[N_INPUT:].set(RUN.leak)
    lif = LIFParams(
        v_th=v_th, leak=leak, r_ref=jnp.zeros((n,), jnp.int32),
        gain=jnp.ones((n,)), i_bias=jnp.zeros((n,)), v_reset=jnp.zeros((n,)))
    return SNNParams(w=w, c=c, w_in=jnp.eye(n) * 2.0, lif=lif)


def readout_net(w: jnp.ndarray) -> SNNParams:
    """64 -> 10 bipartite readout net driven by replayed feature spikes."""
    n = N_HIDDEN + N_CLASSES
    c = jnp.asarray(connectivity.layered([N_HIDDEN, N_CLASSES]), jnp.float32)
    v_th = jnp.ones((n,)).at[N_HIDDEN:].set(RUN.readout_v_th)
    lif = LIFParams(
        v_th=v_th, leak=jnp.zeros((n,)), r_ref=jnp.zeros((n,), jnp.int32),
        gain=jnp.ones((n,)), i_bias=jnp.zeros((n,)), v_reset=jnp.zeros((n,)))
    return SNNParams(w=w, c=c, w_in=jnp.eye(n) * 2.0, lif=lif)


def _clamp(ext_row: jnp.ndarray, n: int, ticks: int) -> jnp.ndarray:
    """Level-coded presentation: clamp a spike vector for ``ticks`` ticks."""
    ext = jnp.zeros((ext_row.shape[0], n)).at[:, : ext_row.shape[1]].set(ext_row)
    return jnp.broadcast_to(ext[None], (ticks,) + ext.shape)


# ---------------------------------------------------------------------------
# stage 1: unsupervised STDP features


@partial(jax.jit, static_argnames=("backend",))
def stdp_present(w, theta, x, *, backend="jnp"):
    """One presentation: learning rollout + host-side homeostasis.

    Two slow register-level loops close around the on-device STDP:
    threshold homeostasis (spikers get harder to fire) and synaptic
    scaling (each feature neuron's fan-in is renormalized to a fixed
    budget, so potentiation on the won pattern costs weight elsewhere --
    receptive fields specialize instead of saturating at w_max).
    """
    n = N_INPUT + N_HIDDEN
    params = feature_net(w, theta)
    ext = _clamp(x[None], n, RUN.ticks_per_sample)
    state = SNNState.zeros((1,), n)
    pstate = PlasticityState.zeros((1,), n)
    eng = dataclasses.replace(FEATURE, backend=backend)
    (_, _, w2), raster = eng.learning_rollout(
        params, state, pstate, ext, RUN.ticks_per_sample,
        plastic_c=plastic_mask())
    ff = w2[:N_INPUT, N_INPUT:]
    scale = RUN.w_total / jnp.maximum(ff.sum(0), 1e-6)
    ff = jnp.clip(ff * scale[None, :], RUN.feature.w_min, RUN.feature.w_max)
    w2 = w2.at[:N_INPUT, N_INPUT:].set(ff)
    counts = raster[:, 0, N_INPUT:].sum(0)
    theta2 = jnp.clip(
        theta + RUN.theta_plus * counts - RUN.theta_drift,
        RUN.theta_min, RUN.theta_max)
    return w2, theta2, counts


@jax.jit
def feature_counts(w, theta, xs):
    """Inference-only feature responses for a batch (no plasticity).

    Returns latency-weighted scores (earlier spike => stronger match --
    the competition variable the WTA actually races on) and the raster.
    """
    n = N_INPUT + N_HIDDEN
    params = feature_net(w, theta)
    ext = _clamp(xs, n, RUN.ticks_per_sample)
    state = SNNState.zeros((xs.shape[0],), n)
    _, raster = INFER.rollout(params, state, ext, RUN.ticks_per_sample)
    ticks = RUN.ticks_per_sample
    lat_w = jnp.arange(ticks, 0, -1, dtype=jnp.float32)  # t=0 -> weight T
    score = jnp.einsum("t,tbn->bn", lat_w, raster[..., N_INPUT:])
    return score, raster


def init_feature_state(rng):
    """Sparse dispersed receptive fields + jittered thresholds: enough
    across-neuron drive variance that threshold crossings spread over
    several ticks, which is what lets the (1-tick-delayed) WTA block pick
    distinct winners."""
    n = N_INPUT + N_HIDDEN
    w = (rng.uniform(RUN.w_init_lo, RUN.w_init_hi, (n, n))
         * (rng.random((n, n)) < RUN.w_init_density)).astype(np.float32)
    theta = rng.uniform(0.0, RUN.theta_init_jitter, N_HIDDEN).astype(np.float32)
    return with_lateral_inhibition(jnp.asarray(w)), jnp.asarray(theta)


def train_features(xtr, seed=0, epochs=2, backend="jnp", log_every=200):
    rng = np.random.default_rng(seed)
    w, theta = init_feature_state(rng)
    seen = 0
    for _ in range(epochs):
        for i in rng.permutation(len(xtr)):
            w, theta, _ = stdp_present(w, theta, jnp.asarray(xtr[i]),
                                       backend=backend)
            seen += 1
            if log_every and seen % log_every == 0:
                wm = w[:N_INPUT, N_INPUT:]
                print(f"  [stdp] {seen} presentations, "
                      f"w mean {float(wm.mean()):.2f} / max {float(wm.max()):.1f}, "
                      f"theta mean {float(theta.mean()):.1f}")
    return w, theta


def neuron_labels(counts: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Label each feature neuron by the class it responds to most (mean),
    normalizing away per-neuron excitability differences first."""
    resp = counts / np.maximum(counts.sum(1, keepdims=True), 1e-6)
    per_class = np.stack([resp[y == d].mean(0) for d in range(N_CLASSES)])
    return per_class.argmax(0)


def cluster_accuracy(counts_te, yte, labels) -> float:
    """Diehl&Cook-style readout: average response within each label group,
    predict the group with the highest mean activity."""
    counts_te = np.asarray(counts_te)
    group = np.zeros((len(counts_te), N_CLASSES))
    for d in range(N_CLASSES):
        members = labels == d
        if members.any():
            group[:, d] = counts_te[:, members].mean(1)
    return float((group.argmax(1) == yte).mean())


# ---------------------------------------------------------------------------
# stage 2: R-STDP readout


@jax.jit
def rstdp_present(w_out, hid_raster, label):
    """One readout presentation: bank eligibility, then terminal reward."""
    n = N_HIDDEN + N_CLASSES
    ticks = hid_raster.shape[0]
    params = readout_net(w_out)
    ext = jnp.zeros((ticks, 1, n)).at[:, 0, :N_HIDDEN].set(hid_raster)
    state = SNNState.zeros((1,), n)
    pstate = PlasticityState.zeros((1,), n)
    (fin, pst, _), raster = READOUT.learning_rollout(
        params, state, pstate, ext, ticks)
    counts = raster[:, 0, N_HIDDEN:].sum(0)
    # exact drive-image tiebreak (classifier.py idiom): count*th + residual v
    score = counts * RUN.readout_v_th + fin.lif.v[0, N_HIDDEN:]
    pred = jnp.argmax(score)
    reward = jnp.where(pred == label, RUN.reward_correct, RUN.reward_wrong)
    # Mozafari-style credit assignment: dopamine gates only the *winning*
    # neuron's synapses (scalar reward + a local "I won" flag) -- right
    # winners reinforce their active inputs, wrong winners unlearn them.
    winner_col = jax.nn.one_hot(N_HIDDEN + pred, params.w.shape[0])
    w2 = apply_reward(
        w_out, pst.elig * winner_col[None, :], reward, RUN.readout, params.c)
    return w2, pred


@jax.jit
def readout_predict(w_out, hid_raster_batch):
    n = N_HIDDEN + N_CLASSES
    ticks = hid_raster_batch.shape[0]
    params = readout_net(w_out)
    b = hid_raster_batch.shape[1]
    ext = jnp.zeros((ticks, b, n)).at[..., :N_HIDDEN].set(hid_raster_batch)
    state = SNNState.zeros((b,), n)
    fin, raster = INFER.rollout(params, state, ext, ticks)
    score = (raster[..., N_HIDDEN:].sum(0) * RUN.readout_v_th
             + fin.lif.v[:, N_HIDDEN:])
    return jnp.argmax(score, axis=-1)


def train_readout(hid, ytr, seed=0, epochs=3):
    """``hid``: (T, B, H) feature spike trains (one rollout, reused --
    the caller already ran feature_counts for the labeling step)."""
    rng = np.random.default_rng(seed + 1)
    n = N_HIDDEN + N_CLASSES
    # random (not constant) init: with identical columns every output spikes
    # identically, eligibility is column-symmetric, and the scalar reward
    # could never break the tie
    w_out = jnp.asarray(rng.uniform(
        0.5 * RUN.readout_w_init, 1.5 * RUN.readout_w_init,
        (n, n)).astype(np.float32))
    for _ in range(epochs):
        for i in rng.permutation(len(ytr)):
            w_out, _ = rstdp_present(w_out, hid[:, i], int(ytr[i]))
    return w_out


# ---------------------------------------------------------------------------
# device readback: learned u8 weights through the UART byte protocol


def readback_roundtrip(w, theta):
    """Quantize learned weights to u8, push through serialize()/load_bytes(),
    and assert the reloaded device produces identical spikes.

    Only the learned excitatory block lives in the streamed u8 weight
    registers; the fixed WTA block is the device-local inhibitory bank
    (reinstalled after load, like ``bias``/``leak`` in classifier.deploy).
    """
    from repro.core import uart
    from repro.plasticity import weights_to_bank

    n = N_INPUT + N_HIDDEN
    bank = RegisterBank(n, weight_layout=WeightLayout.PER_SYNAPSE)
    bank.set_connection_list(routing_mask())
    w_exc = jnp.asarray(w).at[N_INPUT:, N_INPUT:].set(0.0)
    w_u8 = weights_to_bank(bank, w_exc)
    th = np.ones((n,))
    th[N_INPUT:] = np.rint(RUN.v_th_base + np.asarray(theta))
    bank.set_thresholds(th.astype(np.uint8))
    leak = np.zeros((n,))
    leak[N_INPUT:] = RUN.leak
    bank.set_leak(leak.astype(np.uint8))

    payload = bank.serialize()
    received = uart.HostLink().send(payload)
    bank_dev = RegisterBank(n, weight_layout=WeightLayout.PER_SYNAPSE)
    bank_dev.load_bytes(received)
    bank_dev.set_leak(bank.leak)            # device-local regs (not streamed)
    assert bank_dev.serialize() == payload, "register payload not byte-exact"
    assert np.array_equal(bank_dev.weights, w_u8), "u8 weights changed in flight"

    x, _ = mnist.load(n_per_class=4, seed=7)
    ext = _clamp(jnp.asarray(mnist.to_spikes(x)), n, RUN.ticks_per_sample)

    def spikes(b):
        params = params_from_registers(b)
        params = dataclasses.replace(
            params, w=with_lateral_inhibition(params.w))
        state = SNNState.zeros((ext.shape[1],), n)
        _, raster = INFER.rollout(params, state, ext, RUN.ticks_per_sample)
        return np.asarray(raster)

    before, after = spikes(bank), spikes(bank_dev)
    assert np.array_equal(before, after), "spikes differ after round trip"
    return bank_dev, int(before[..., N_INPUT:].sum())


# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer samples/epochs (CI smoke)")
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"],
                    help="plasticity/tick backend for stage 1")
    args = ap.parse_args()

    cfg = get_bundle("mnist-stdp").model
    n_per_class = 16 if args.fast else 40
    epochs = 2 if args.fast else 3
    x, y = mnist.load(n_per_class=n_per_class, seed=0)
    spikes = mnist.to_spikes(x)
    n_test = len(y) // 5
    xtr, ytr = spikes[n_test:], y[n_test:]
    xte, yte = spikes[:n_test], y[:n_test]
    print(f"{cfg.name}: {len(ytr)} train / {len(yte)} test, "
          f"{N_INPUT}->{N_HIDDEN}->{N_CLASSES} neurons, "
          f"{RUN.ticks_per_sample} ticks/presentation")

    # baseline: random init, no learning
    w0, theta0 = init_feature_state(np.random.default_rng(0))
    c0_tr, _ = feature_counts(w0, theta0, jnp.asarray(xtr))
    c0_te, _ = feature_counts(w0, theta0, jnp.asarray(xte))
    acc0 = cluster_accuracy(
        np.asarray(c0_te), yte, neuron_labels(np.asarray(c0_tr), ytr))

    # stage 1: unsupervised STDP
    print("stage 1: unsupervised STDP feature learning")
    w, theta = train_features(xtr, epochs=epochs, backend=args.backend)
    ctr, rtr = feature_counts(w, theta, jnp.asarray(xtr))
    cte, rte = feature_counts(w, theta, jnp.asarray(xte))
    labels = neuron_labels(np.asarray(ctr), ytr)
    acc1 = cluster_accuracy(np.asarray(cte), yte, labels)
    print(f"  feature-cluster accuracy: random init {acc0:.3f} -> "
          f"STDP {acc1:.3f} (chance {1 / N_CLASSES:.2f})")
    print(f"  distinct class labels among {N_HIDDEN} features: "
          f"{len(set(labels.tolist()))}")

    # stage 2: R-STDP readout
    print("stage 2: R-STDP readout (terminal dopamine reward)")
    w_out = train_readout(rtr[..., N_INPUT:], ytr,
                          epochs=3 if args.fast else 8)
    pred = np.asarray(readout_predict(w_out, rte[..., N_INPUT:]))
    acc2 = float((pred == yte).mean())
    print(f"  end-to-end test accuracy: {acc2:.3f} (chance {1 / N_CLASSES:.2f})")

    # device readback
    bank_dev, n_spikes = readback_roundtrip(w, theta)
    bd = bank_dev.breakdown()
    print("device readback: learned u8 weights -> serialize -> UART -> load")
    print(f"  {bd.total} transactions ({bd.connection_list} CL + "
          f"{bd.thresholds} th + {bd.weights} w + {bd.impulses} imp), "
          f"spikes identical before/after ({n_spikes} hidden spikes probed)")

    ok = acc1 > max(2 / N_CLASSES, acc0) and acc2 > 2 / N_CLASSES
    print("PASS" if ok else "FAIL", "- on-device learning separates classes")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
