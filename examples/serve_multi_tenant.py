"""Multi-tenant SNN serving: many resident networks, one compiled program.

The paper's headline is that swapping a network is a *parameter
download* -- never a re-synthesis. The serving restatement: S tenant
networks (heterogeneous topologies, thresholds, leaks; some frozen, one
learning online) time-share one compiled tick program, vmapped over a
slot axis. Admitting a request = writing a slot's registers. The demo
asserts the whole run compiles exactly once.

  PYTHONPATH=src python examples/serve_multi_tenant.py [--fast]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core import connectivity
from repro.core.registers import RegisterBank, WeightLayout
from repro.launch.serve import (
    SNNServer, make_demo_requests, make_demo_tenants,
)

jax.config.update("jax_platform_name", "cpu")


def iris_like_bank(seed: int = 0) -> RegisterBank:
    """The paper's Iris shape (4 input -> 3 output) as a register image."""
    n = 7
    bank = RegisterBank(n, weight_layout=WeightLayout.PER_SYNAPSE)
    c = connectivity.layered([4, 3])
    bank.set_connection_list(c)
    rng = np.random.default_rng(seed)
    bank.set_weights((rng.integers(60, 200, (n, n)) * c).astype(np.uint8))
    bank.set_thresholds(np.full((n,), 100, np.uint8))
    bank.set_refractory(2)
    return bank


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args(argv)

    n_requests = 12 if args.fast else 48
    server = SNNServer(n_max=24, slots=args.slots, max_ticks=12)

    # 8 heterogeneous demo tenants (last one plastic) + the paper's Iris net.
    names = make_demo_tenants(server, 8, seed=0)
    server.add_tenant("iris", iris_like_bank(), n_in=4, n_out=3)
    names.append("iris")
    plastic = [t.name for t in server.tenants.values() if t.plastic]
    print(f"fabric n_max={server.n_max}, slots={server.slots}: "
          f"{len(server.tenants)} resident tenants ({', '.join(names)}); "
          f"plastic: {plastic}")

    reqs = make_demo_requests(server, names, n_requests, seed=1)

    w_plastic0 = np.asarray(server.tenants[plastic[0]].params.w).copy()
    stats = server.serve(reqs)
    for k, v in stats.items():
        if k not in ("preds", "results"):
            print(f"  {k}: {v}")

    assert stats["compiles"] == 1, "tenant swaps must not recompile"
    assert stats["recompiles_after_warmup"] == 0
    w_plastic1 = np.asarray(server.tenants[plastic[0]].params.w)
    drift = float(np.abs(w_plastic1 - w_plastic0).sum())
    print(f"  plastic tenant weight drift across waves: {drift:.1f} "
          "(frozen tenants: bit-identical by construction)")
    assert drift > 0, "the plastic tenant never learned"

    # Per-tenant activity from the wave telemetry riding the scan carry:
    # spike rates, refractory occupancy, and (for the plastic tenant) the
    # accumulated |dw| -- all measured on-device, no extra rollouts.
    print("per-tenant activity:")
    for name, row in server.tenant_report().items():
        print(f"  {name:>10}: requests={row['requests']:>2} "
              f"spike_rate={row['spike_rate']:.3f} "
              f"refractory={row['refractory_occupancy']:.3f} "
              f"dw_l1={row['dw_l1']:.1f}"
              f"{'  [plastic]' if row['plastic'] else ''}")
    assert server.tenant_report()[plastic[0]]["dw_l1"] > 0

    # Continuous admission: same tenants, same compiled program, but slots
    # retire and refill individually instead of draining whole waves -- so
    # short requests stop waiting on the longest one in their wave.
    cont = server.serve_continuous(
        make_demo_requests(server, names, n_requests, seed=2))
    assert cont["recompiles_after_warmup"] == 0, \
        "slot refill must reuse the wave path's compiled chunk program"
    print(f"continuous admission: served {cont['requests_served']} more "
          f"requests, mean TTFT {cont['mean_ttft_s'] * 1e3:.1f} ms, "
          f"p99 {cont['p99_ttft_s'] * 1e3:.1f} ms, 0 recompiles")

    print("PASS - one compiled tick program served "
          f"{stats['n_tenants']} networks / {stats['n_requests']} requests")
    return stats


if __name__ == "__main__":
    main()
