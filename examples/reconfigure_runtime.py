"""Runtime reconfiguration without re-synthesis (the paper's core claim).

One 74-neuron fabric is compiled ONCE; we then run the Iris task and the
MNIST task on it purely by rewriting the register bank (connection list,
weights, thresholds) -- the Iris net occupies neurons 0..6 of the fabric,
MNIST all 74. Zero retraces, zero recompiles: connectivity is data.

  PYTHONPATH=src python examples/reconfigure_runtime.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import connectivity
from repro.core.engine import TickEngine
from repro.core.lif import LIFParams
from repro.core.network import SNNParams, SNNState, params_from_registers
from repro.core.registers import RegisterBank, WeightLayout

ENGINE = TickEngine()  # one resident tick datapath; networks are register data

N = 74  # one physical fabric, sized for the larger task


def make_bank() -> RegisterBank:
    return RegisterBank(N, weight_layout=WeightLayout.PER_SYNAPSE)


def program_iris(bank: RegisterBank) -> None:
    """Iris 4->3 net embedded in neurons 0..6 of the 74-neuron fabric."""
    c = np.zeros((N, N), np.bool_)
    c[:7, :7] = connectivity.layered([4, 3])
    bank.set_connection_list(c)
    w = np.zeros((N, N), np.uint8)
    w[:4, 4:7] = np.random.default_rng(0).integers(1, 200, (4, 3))
    bank.set_weights(w)
    th = np.zeros(N, np.uint8)
    th[4:7] = 100
    bank.set_thresholds(th)


def program_mnist(bank: RegisterBank) -> None:
    """MNIST 64->10 net across the full fabric."""
    c = np.zeros((N, N), np.bool_)
    c[:74, :74] = connectivity.layered([64, 10])
    bank.set_connection_list(c)
    w = np.zeros((N, N), np.uint8)
    w[:64, 64:74] = np.random.default_rng(1).integers(1, 60, (64, 10))
    bank.set_weights(w)
    th = np.zeros(N, np.uint8)
    th[64:74] = 200
    bank.set_thresholds(th)


def main():
    bank = make_bank()
    trace_count = {"n": 0}

    def tick_program(w, c, v_th, ext):
        trace_count["n"] += 1  # counted at TRACE time only
        lif = LIFParams.make(N, v_th=1.0)
        lif = LIFParams(v_th=v_th, leak=lif.leak, r_ref=lif.r_ref,
                        gain=lif.gain, i_bias=lif.i_bias, v_reset=lif.v_reset)
        p = SNNParams(w=w, c=c, w_in=jnp.eye(N), lif=lif)
        state = SNNState.zeros((ext.shape[1],), N)
        _, raster = ENGINE.rollout(p, state, ext, ext.shape[0])
        return raster

    tick = jax.jit(tick_program)

    def run(task_name):
        p = params_from_registers(bank)
        ext = jnp.zeros((4, 8, N)).at[0, :, :4].set(1.0)
        t0 = time.time()
        raster = jax.block_until_ready(tick(p.w, p.c, p.lif.v_th, ext))
        return time.time() - t0, float(raster.sum())

    program_iris(bank)
    t_iris, s_iris = run("iris")
    print(f"iris    : {t_iris*1e3:7.1f} ms (includes compile), "
          f"{s_iris:.0f} spikes, traces so far: {trace_count['n']}")

    program_mnist(bank)   # <- pure register rewrite: same shapes
    t_mnist, s_mnist = run("mnist")
    print(f"mnist   : {t_mnist*1e3:7.1f} ms (no recompile), "
          f"{s_mnist:.0f} spikes, traces so far: {trace_count['n']}")

    program_iris(bank)    # swap back
    t_back, s_back = run("iris-again")
    print(f"iris(2) : {t_back*1e3:7.1f} ms, traces so far: {trace_count['n']}")

    assert trace_count["n"] == 1, "reconfiguration must not retrace!"
    print("\nOK: three reconfigurations, ONE compiled program "
          "(the paper's no-re-synthesis property, in jit form)")


if __name__ == "__main__":
    main()
