"""Train an LM end-to-end with the production driver (deliverable b).

Demonstrates the full substrate on CPU: counter-based data pipeline,
jitted train step (AdamW, clipping, warmup-cosine), async checkpoints,
restart-exactness. Default is the reduced SmolLM config so it finishes in
minutes on CPU; pass ``--full --steps N`` on a real pod for the 135M run
(same code path; the driver scales via --mesh single|multi).

  PYTHONPATH=src python examples/train_lm.py
"""
import sys
import tempfile

from repro.launch import train as train_mod


def main():
    full = "--full" in sys.argv
    with tempfile.TemporaryDirectory() as d:
        argv = [
            "--arch", "smollm-135m",
            "--steps", "60",
            "--seq-len", "64",
            "--global-batch", "8",
            "--ckpt-dir", d,
            "--ckpt-every", "20",
            "--log-every", "5",
            "--peak-lr", "1e-3",
        ]
        if not full:
            argv.append("--smoke")
        losses = train_mod.main(argv)
        assert losses[-1] < losses[0], "loss must decrease"
        print(f"\nloss decreased {losses[0]:.3f} -> {losses[-1]:.3f} over "
              f"{len(losses)} steps (checkpoints + resume exercised)")


if __name__ == "__main__":
    main()
