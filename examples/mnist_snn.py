"""MNIST-8x8 on the 74-neuron system (paper §III.B + Fig. 6/7).

Full pipeline: 8x8 grayscale -> binarize -> 64 input spikes -> 74-neuron
SNN -> 10 output neurons -> "neuron with the highest accumulated
activation" readout. Reports the paper's 898-transaction register-update
arithmetic for this exact system.

  PYTHONPATH=src python examples/mnist_snn.py
"""

from repro.configs import get_bundle
from repro.core import classifier
from repro.core.registers import TimingModel, transaction_breakdown
from repro.data import mnist


def main():
    cfg = get_bundle("mnist-snn").model
    x, y = mnist.load(n_per_class=40, seed=0)
    spikes = mnist.to_spikes(x)          # binarized: '1' spikes, '0' silent
    n_test = len(y) // 5
    xtr, ytr = spikes[n_test:], y[n_test:]
    xte, yte = spikes[:n_test], y[:n_test]
    print(f"{len(ytr)} train / {len(yte)} test images, "
          f"{spikes.shape[1]} input neurons, refractory={cfg.n_ticks} ticks")

    model = classifier.train(xtr, ytr, cfg)
    dep = classifier.deploy(model, n_neurons=cfg.n_neurons)

    bd = transaction_breakdown(74)   # the paper's per-neuron weight layout
    print(f"\npaper §III.B register update ({dep.bank.n} neurons):")
    print(f"  CL {bd.connection_list} + th {bd.thresholds} + w {bd.weights}"
          f" + imp {bd.impulses} = {bd.total} transactions")
    print(f"  paper timing: {bd.time_s(TimingModel.PAPER)*1e3:.2f} ms "
          "(per-bit-time arithmetic); 8N1 wire: "
          f"{bd.time_s(TimingModel.WIRE_8N1)*1e3:.1f} ms")

    pred = classifier.predict_int(dep, xte)
    acc = classifier.accuracy(pred, yte)
    per_class = {d: float((pred[yte == d] == d).mean()) for d in range(10)}
    print(f"\ninteger-datapath test accuracy: {acc:.3f}")
    print("per-class:", {k: round(v, 2) for k, v in per_class.items()})
    print("all classes recognized:", all(v > 0 for v in per_class.values()))


if __name__ == "__main__":
    main()
