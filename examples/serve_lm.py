"""Serve a small LM with batched requests (the paper's kind: inference).

Wave-batched serving of SmolLM-135M -- REAL full-size config by default
(135M params run fine on CPU for a short demo); ``--smoke`` for the tiny
config. One compiled prefill + one compiled decode program serve every
request; like the paper's FPGA, swapping requests touches only state.

  PYTHONPATH=src python examples/serve_lm.py --smoke
  PYTHONPATH=src python examples/serve_lm.py            # full 135M
"""
import sys

from repro.launch import serve as serve_mod


def main():
    argv = ["--arch", "smollm-135m", "--requests", "6", "--max-new", "8",
            "--slots", "3", "--max-len", "48"]
    if "--smoke" in sys.argv:
        argv.append("--smoke")
    stats = serve_mod.main(argv)
    assert stats["n_requests"] == 6
    assert stats["new_tokens"] >= 6 * 8
    print("\nserved all requests through one resident compiled program")


if __name__ == "__main__":
    main()
