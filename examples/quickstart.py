"""Quickstart: the paper's Iris pipeline in ~40 lines (paper §III.A + §IV).

Host PC side: load data, encode features to integer impulse levels, train
the 4->3 LIF network offline. Device side: download through the UART
register protocol, run bit-faithful integer inference.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_bundle
from repro.core import classifier, encoding
from repro.core.registers import TimingModel
from repro.data import iris


def main():
    cfg = get_bundle("iris-snn").model
    print(f"network: {cfg.layer_sizes[0]} input + {cfg.layer_sizes[1]} output "
          f"LIF neurons (Fig. 4), threshold=1, {cfg.n_ticks} ticks")

    # --- host preprocessing (paper §IV): normalize + quantize to levels ---
    x, y = iris.load(seed=0)
    levels = np.asarray(encoding.level_encode(iris.normalize(x), levels=4))
    (xtr, ytr), (xte, yte) = iris.train_test_split(levels, y)

    # --- offline training (surrogate gradient) ---
    model = classifier.train(xtr, ytr, cfg)
    acc_f = classifier.accuracy(classifier.predict_float(model, xte), yte)
    print(f"float model test accuracy: {acc_f:.3f}")

    # --- UART download: quantize -> register bank -> serialize -> reload ---
    dep = classifier.deploy(model, n_neurons=cfg.n_neurons)
    bd = dep.bank.breakdown()
    print(f"register download: {bd.total} bytes "
          f"({bd.time_s(TimingModel.PAPER)*1e3:.2f} ms paper model / "
          f"{bd.time_s(TimingModel.WIRE_8N1)*1e3:.2f} ms on a real 9600-8N1 wire)")

    # --- device-side integer inference (the FPGA datapath) ---
    pred = classifier.predict_int(dep, xte)
    acc_i = classifier.accuracy(pred, yte)
    print(f"integer (u8 registers, i32 accumulate) test accuracy: {acc_i:.3f}")
    print("sample predictions:", pred[:10], "labels:", yte[:10])


if __name__ == "__main__":
    main()
